//! Trace I/O round-trip invariants: writing any trace to `.mtrace` and
//! re-ingesting it is lossless at the IR level (near/far annotation bits
//! included) and produces **bit-identical** simulation statistics; and
//! trace-backed harness points shard deterministically under `--jobs N`.

use std::path::PathBuf;

use malekeh::compiler;
use malekeh::config::{GpuConfig, Scheme};
use malekeh::harness::{ExpOpts, Runner};
use malekeh::isa::OpClass;
use malekeh::sim::{run_workload, Simulator};
use malekeh::trace::io::{self, Transform};
use malekeh::trace::{find, table2, KernelTrace, Workload};

fn cfg(scheme: Scheme) -> GpuConfig {
    let mut c = GpuConfig::table1_baseline().with_scheme(scheme);
    c.num_sms = 1;
    c
}

/// Unique temp path per test so parallel test binaries never collide.
fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("malekeh_test_{}_{name}", std::process::id()))
}

#[test]
fn ir_roundtrips_for_every_table2_benchmark() {
    for b in table2() {
        let mut t = KernelTrace::generate(b, 4, 0xC0FFEE);
        compiler::profile_and_annotate(&mut t, 2, 12);
        let text = io::write_string(&t).unwrap();
        let back = io::read_str(&text)
            .unwrap_or_else(|e| panic!("{}: {e}", b.name));
        assert_eq!(back.name, t.name, "{}", b.name);
        assert_eq!(back.kernel_id, t.kernel_id, "{}", b.name);
        assert_eq!(back.warps, t.warps, "{}: IR not preserved", b.name);
    }
}

#[test]
fn replay_is_bit_identical_including_annotation_bits() {
    for (bench, scheme) in [
        ("kmeans", Scheme::MALEKEH),
        ("gemm_t1", Scheme::BOW),
        ("b+tree", Scheme::BASELINE),
    ] {
        let c = cfg(scheme);
        let b = find(bench).unwrap();
        let mut t =
            KernelTrace::generate(b, c.num_sms * c.warps_per_sm, c.seed);
        compiler::profile_and_annotate(&mut t, 2, c.rthld);
        let direct = Simulator::new(&c, &t).run();
        let back = io::read_str(&io::write_string(&t).unwrap()).unwrap();
        assert!(back.has_annotations(), "{bench}: bits lost in the file");
        let replayed = Simulator::new(&c, &back).run();
        assert_eq!(
            direct.fingerprint(),
            replayed.fingerprint(),
            "{bench}/{scheme}: replay diverged"
        );
    }
}

#[test]
fn raw_recording_matches_builtin_workload_run() {
    // a raw (unannotated) recording goes through the same compiler pass as
    // the builtin path, so the file-backed point must reproduce
    // run_benchmark exactly
    let c = cfg(Scheme::MALEKEH);
    let path = tmp("kmeans_raw.mtrace");
    let t = KernelTrace::generate(
        find("kmeans").unwrap(),
        c.num_sms * c.warps_per_sm,
        c.seed,
    );
    io::write_path(&path, &t).unwrap();
    let builtin = run_workload(&c, &Workload::builtin("kmeans"), 2).unwrap();
    let replay = run_workload(&c, &Workload::trace_file(&path), 2).unwrap();
    assert_eq!(builtin.fingerprint(), replay.fingerprint());
    std::fs::remove_file(&path).ok();
}

#[test]
fn annotated_recording_matches_builtin_workload_run() {
    // recording *after* annotation bakes the bits into the file; replay
    // must trust them and still match the builtin run bit for bit
    let c = cfg(Scheme::MALEKEH);
    let path = tmp("kmeans_annotated.mtrace");
    let mut t = KernelTrace::generate(
        find("kmeans").unwrap(),
        c.num_sms * c.warps_per_sm,
        c.seed,
    );
    compiler::profile_and_annotate(&mut t, 2, c.rthld);
    io::write_path(&path, &t).unwrap();
    let builtin = run_workload(&c, &Workload::builtin("kmeans"), 2).unwrap();
    let replay = run_workload(&c, &Workload::trace_file(&path), 2).unwrap();
    assert_eq!(builtin.fingerprint(), replay.fingerprint());
    std::fs::remove_file(&path).ok();
}

#[test]
fn trace_points_shard_deterministically() {
    let path = tmp("shard.mtrace");
    let t = KernelTrace::generate(find("nn").unwrap(), 32, 0xC0FFEE);
    io::write_path(&path, &t).unwrap();
    let fingerprint_at = |jobs: usize| {
        let runner = Runner::new(ExpOpts {
            num_sms: 1,
            seed: 0xC0FFEE,
            profile_warps: 2,
            quick: true,
            jobs,
            sim_threads: 1,
            store_dir: None,
        });
        let mut plan = runner.plan();
        plan.add("kmeans", Scheme::BASELINE);
        plan.add_trace(&path, Scheme::BASELINE);
        plan.add_trace(&path, Scheme::MALEKEH);
        runner.execute(&plan);
        let a = runner.run("kmeans", Scheme::BASELINE);
        let b = runner.run_trace(&path, Scheme::BASELINE);
        let c = runner.run_trace(&path, Scheme::MALEKEH);
        assert_eq!(runner.cached(), 3, "trace points must cache distinctly");
        a.fingerprint()
            ^ b.fingerprint().rotate_left(1)
            ^ c.fingerprint().rotate_left(2)
    };
    assert_eq!(
        fingerprint_at(1),
        fingerprint_at(4),
        "trace-backed plan points diverged across worker counts"
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn transformed_traces_serialise_and_replay() {
    let t = KernelTrace::generate(find("hotspot").unwrap(), 8, 1);
    let out = io::apply_all(
        &t,
        &[
            Transform::WarpSubsample { keep_one_in: 2 },
            Transform::InstructionWindow { start: 10, len: 50 },
            Transform::RegisterRemap { pairs: vec![(2, 200)] },
        ],
    );
    assert_eq!(out.warps.len(), 4);
    for w in &out.warps {
        assert!(w.len() <= 51);
        assert_eq!(w.last().unwrap().op, OpClass::Exit);
        assert!(w
            .iter()
            .all(|i| !i.sources().contains(&2) && !i.dests().contains(&2)));
    }
    let back = io::read_str(&io::write_string(&out).unwrap()).unwrap();
    assert_eq!(out.warps, back.warps);
    // and the transformed trace still simulates to completion
    let stats = malekeh::sim::run_trace(&cfg(Scheme::MALEKEH), back, 2, false);
    assert_eq!(stats.warps_retired, 4);
}

#[test]
fn subsampled_replay_keeps_headline_direction() {
    // scenario scaling: a 1-in-4 warp subsample is a smaller but still
    // representative workload — Malekeh must keep a nonzero hit ratio on it
    let c = cfg(Scheme::MALEKEH);
    let full = KernelTrace::generate(
        find("kmeans").unwrap(),
        c.num_sms * c.warps_per_sm,
        c.seed,
    );
    let quarter = Transform::WarpSubsample { keep_one_in: 4 }.apply(&full);
    assert_eq!(quarter.warps.len(), 8);
    let stats = malekeh::sim::run_trace(&c, quarter, 2, false);
    assert_eq!(stats.warps_retired, 8);
    assert!(stats.rf_hit_ratio() > 0.1, "hit {}", stats.rf_hit_ratio());
}
