"""L2 model tests: annotation composition, histogram semantics, energy
normalisation, and AOT lowering (HLO text emission)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.constants import CAP, RTHLD
from compile.kernels import ref


class TestAnnotate:
    def test_near_far_binarisation(self):
        # distances: 1 (near), RTHLD (near), RTHLD+1 (far), CAP (far)
        ids = np.array([[1, 1, 2, 2, 3, 3, 4]], dtype=np.int32)
        pos = np.array([[0, 1, 2, 2 + RTHLD, 20, 21 + RTHLD, 99]], dtype=np.int32)
        rw = np.ones_like(ids)
        dist, near, hist = model.annotate(ids, pos, rw)
        dist, near = np.asarray(dist), np.asarray(near)
        assert dist[0, 0] == 1 and near[0, 0] == 1
        assert dist[0, 2] == RTHLD and near[0, 2] == 1
        assert dist[0, 4] == RTHLD + 1 and near[0, 4] == 0
        assert dist[0, 6] == CAP and near[0, 6] == 0

    def test_dead_value_is_far_and_not_in_histogram(self):
        ids = np.array([[9, 9]], dtype=np.int32)
        pos = np.array([[0, 5]], dtype=np.int32)
        rw = np.array([[1, 0]], dtype=np.int32)  # read then redefinition
        dist, near, hist = model.annotate(ids, pos, rw)
        assert np.asarray(dist)[0, 0] == -2  # DEAD
        assert np.asarray(near)[0, 0] == 0  # far
        # only the write's own (capped) reuse shows up
        assert np.asarray(hist).sum() == 1

    def test_histogram_matches_ref(self):
        rng = np.random.default_rng(5)
        ids = rng.integers(0, 10, size=(3, 64)).astype(np.int32)
        pos = np.cumsum(rng.integers(0, 2, size=(3, 64)), axis=1).astype(np.int32)
        rw = (rng.random(size=(3, 64)) < 0.7).astype(np.int32)
        dist, _, hist = model.annotate(ids, pos, rw)
        np.testing.assert_array_equal(
            np.asarray(hist), ref.histogram_ref(np.asarray(dist))
        )

    def test_padding_ignored_in_histogram(self):
        ids = np.full((1, 32), -1, dtype=np.int32)
        pos = np.zeros((1, 32), dtype=np.int32)
        _, near, hist = model.annotate(ids, pos, np.ones_like(ids))
        assert np.asarray(hist).sum() == 0
        assert (np.asarray(near) == -1).all()

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31))
    def test_property_hist_total_equals_live_accesses(self, seed):
        rng = np.random.default_rng(seed)
        ids = rng.integers(-1, 8, size=(2, 48)).astype(np.int32)
        pos = np.cumsum(rng.integers(0, 2, size=(2, 48)), axis=1).astype(np.int32)
        rw = (rng.random(size=(2, 48)) < 0.6).astype(np.int32)
        dist, _, hist = model.annotate(ids, pos, rw)
        live = int((np.asarray(dist) >= 0).sum())
        assert np.asarray(hist).sum() == live
        assert live <= int((ids >= 0).sum())


class TestEnergyModel:
    def test_normalized_row0_is_one(self):
        rng = np.random.default_rng(11)
        counts = rng.uniform(1, 100, size=(8, 8)).astype(np.float32)
        costs = rng.uniform(0.5, 2, size=(8,)).astype(np.float32)
        e, norm = model.energy(counts, costs)
        assert abs(float(np.asarray(norm)[0]) - 1.0) < 1e-6
        np.testing.assert_allclose(
            np.asarray(e), ref.rf_energy_ref(counts, costs), rtol=1e-5
        )

    def test_zero_baseline_guard(self):
        counts = np.zeros((4, 8), np.float32)
        counts[1] = 1.0
        costs = np.ones((8,), np.float32)
        _, norm = model.energy(counts, costs)
        assert np.isfinite(np.asarray(norm)).all()


class TestAotLowering:
    def test_all_artifacts_lower_to_hlo_text(self, tmp_path):
        from compile import aot

        aot.build(str(tmp_path))
        names = {p.name for p in tmp_path.iterdir()}
        assert {
            "reuse_annotate.hlo.txt",
            "rf_energy.hlo.txt",
            "mma_gemm.hlo.txt",
            "manifest.txt",
        } <= names
        for n in names:
            if n.endswith(".hlo.txt"):
                text = (tmp_path / n).read_text()
                assert text.startswith("HloModule"), f"{n} is not HLO text"
                assert "ENTRY" in text

    def test_manifest_mentions_constants(self, tmp_path):
        from compile import aot

        aot.build(str(tmp_path), only=["rf_energy"])
        manifest = (tmp_path / "manifest.txt").read_text()
        assert f"rthld={RTHLD}" in manifest
        assert "rf_energy.hlo.txt" in manifest
