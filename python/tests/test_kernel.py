"""Kernel-vs-reference correctness: the CORE numeric signal of the compile
path. Hypothesis sweeps shapes/contents for the reuse kernel and the GEMM."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.constants import CAP, DEAD, WINDOW
from compile.kernels import ref
from compile.kernels.energy import rf_energy
from compile.kernels.mma_gemm import mma_gemm
from compile.kernels.reuse import reuse_distances


def make_stream(rng, w, l, nregs, pad_frac=0.1, read_frac=0.7):
    """Random access stream: ids in [0, nregs), monotone instruction pos,
    mixed read/write accesses, trailing padding."""
    ids = rng.integers(0, nregs, size=(w, l)).astype(np.int32)
    # positions: accesses grouped ~3 per instruction
    pos = np.cumsum(rng.integers(0, 2, size=(w, l)), axis=1).astype(np.int32)
    rw = (rng.random(size=(w, l)) < read_frac).astype(np.int32)
    npad = int(l * pad_frac)
    if npad:
        ids[:, l - npad :] = -1
    return ids, pos, rw


def all_reads(ids):
    return np.ones_like(ids, dtype=np.int32)


# ---------------------------------------------------------------- reuse ----


class TestReuseKernel:
    def test_simple_known_answer(self):
        # ids: r5 reused at distance 2 instructions, r7 never reused
        ids = np.array([[5, 7, 5, -1]], dtype=np.int32)
        pos = np.array([[0, 1, 2, 3]], dtype=np.int32)
        out = np.asarray(reuse_distances(ids, pos, all_reads(ids)))
        assert out[0, 0] == 2  # r5 -> next use 2 instructions later
        assert out[0, 1] == CAP  # r7 never reused within window
        assert out[0, 2] == CAP
        assert out[0, 3] == -1  # padding

    def test_redefinition_marks_value_dead(self):
        # r5 read, then WRITTEN before any read -> first access is dead
        ids = np.array([[5, 5, 5]], dtype=np.int32)
        pos = np.array([[0, 1, 2]], dtype=np.int32)
        rw = np.array([[1, 0, 1]], dtype=np.int32)  # read, write, read
        out = np.asarray(reuse_distances(ids, pos, rw))
        assert out[0, 0] == DEAD  # killed by the write at pos 1
        assert out[0, 1] == 1  # the write's value is read at distance 1

    def test_same_instruction_reuse_is_zero(self):
        ids = np.array([[3, 3]], dtype=np.int32)
        pos = np.array([[4, 4]], dtype=np.int32)  # same dynamic instruction
        out = np.asarray(reuse_distances(ids, pos, all_reads(ids)))
        assert out[0, 0] == 0

    def test_reuse_beyond_window_is_capped(self):
        l = WINDOW + 8
        ids = np.full((1, l), 100, dtype=np.int32)
        ids[0, 1:-1] = np.arange(l - 2)  # middle all distinct
        pos = np.arange(l, dtype=np.int32).reshape(1, l)
        out = np.asarray(reuse_distances(ids, pos, all_reads(ids)))
        # first access's reuse is l-1 > WINDOW accesses away -> capped
        assert out[0, 0] == CAP

    def test_matches_reference_dense(self):
        rng = np.random.default_rng(0)
        ids, pos, rw = make_stream(rng, 4, 96, nregs=12)
        got = np.asarray(reuse_distances(ids, pos, rw))
        want = ref.reuse_distances_ref(ids, pos, rw)
        np.testing.assert_array_equal(got, want)

    def test_matches_reference_sparse_ids(self):
        rng = np.random.default_rng(1)
        ids, pos, rw = make_stream(rng, 2, 128, nregs=200)  # few repeats
        got = np.asarray(reuse_distances(ids, pos, rw))
        want = ref.reuse_distances_ref(ids, pos, rw)
        np.testing.assert_array_equal(got, want)

    def test_all_padding_row(self):
        ids = np.full((2, 16), -1, dtype=np.int32)
        pos = np.zeros((2, 16), dtype=np.int32)
        out = np.asarray(reuse_distances(ids, pos, all_reads(ids)))
        assert (out == -1).all()

    @settings(max_examples=25, deadline=None)
    @given(
        w=st.integers(1, 4),
        l=st.integers(8, 160),
        nregs=st.integers(1, 64),
        seed=st.integers(0, 2**32 - 1),
        pad=st.sampled_from([0.0, 0.1, 0.5]),
    )
    def test_property_matches_reference(self, w, l, nregs, seed, pad):
        rng = np.random.default_rng(seed)
        ids, pos, rw = make_stream(rng, w, l, nregs, pad_frac=pad)
        got = np.asarray(reuse_distances(ids, pos, rw))
        want = ref.reuse_distances_ref(ids, pos, rw)
        np.testing.assert_array_equal(got, want)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1))
    def test_property_distances_bounded(self, seed):
        rng = np.random.default_rng(seed)
        ids, pos, rw = make_stream(rng, 2, 64, 8)
        out = np.asarray(reuse_distances(ids, pos, rw))
        valid = out[ids >= 0]
        assert (((valid >= 0) & (valid <= CAP)) | (valid == DEAD)).all()
        assert (out[ids < 0] == -1).all()


# ----------------------------------------------------------------- gemm ----


class TestGemmKernel:
    @pytest.mark.parametrize(
        "m,n,k,bm,bn,bk",
        [
            (128, 128, 128, 128, 128, 128),  # single block
            (256, 128, 128, 128, 128, 128),  # grid over m
            (128, 256, 256, 128, 128, 128),  # grid over n and k
            (64, 64, 192, 32, 64, 64),       # non-square blocks, 3 k-steps
        ],
    )
    def test_matches_reference_shapes(self, m, n, k, bm, bn, bk):
        rng = np.random.default_rng(m + n + k)
        x = rng.standard_normal((m, k), dtype=np.float32)
        y = rng.standard_normal((k, n), dtype=np.float32)
        got = np.asarray(mma_gemm(x, y, bm=bm, bn=bn, bk=bk))
        np.testing.assert_allclose(got, ref.gemm_ref(x, y), rtol=1e-4, atol=1e-4)

    def test_bf16_inputs_accumulate_f32(self):
        import jax.numpy as jnp

        rng = np.random.default_rng(7)
        x = rng.standard_normal((128, 128)).astype(np.float32)
        y = rng.standard_normal((128, 128)).astype(np.float32)
        got = np.asarray(
            mma_gemm(jnp.asarray(x, jnp.bfloat16), jnp.asarray(y, jnp.bfloat16))
        )
        assert got.dtype == np.float32
        np.testing.assert_allclose(got, ref.gemm_ref(x, y), rtol=5e-2, atol=5e-1)

    def test_identity(self):
        x = np.eye(128, dtype=np.float32)
        y = np.arange(128 * 128, dtype=np.float32).reshape(128, 128) / 1e3
        got = np.asarray(mma_gemm(x, y))
        np.testing.assert_allclose(got, y, rtol=1e-6)

    def test_shape_mismatch_raises(self):
        x = np.zeros((128, 128), np.float32)
        y = np.zeros((64, 128), np.float32)
        with pytest.raises(AssertionError):
            mma_gemm(x, y)

    @settings(max_examples=8, deadline=None)
    @given(
        mi=st.integers(1, 2),
        ni=st.integers(1, 2),
        ki=st.integers(1, 3),
        seed=st.integers(0, 2**32 - 1),
    )
    def test_property_block_multiples(self, mi, ni, ki, seed):
        bm = bn = bk = 32
        m, n, k = mi * bm, ni * bn, ki * bk
        rng = np.random.default_rng(seed)
        x = rng.standard_normal((m, k), dtype=np.float32)
        y = rng.standard_normal((k, n), dtype=np.float32)
        got = np.asarray(mma_gemm(x, y, bm=bm, bn=bn, bk=bk))
        np.testing.assert_allclose(got, ref.gemm_ref(x, y), rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------- energy ----


class TestEnergyKernel:
    def test_matches_reference(self):
        rng = np.random.default_rng(3)
        counts = rng.uniform(0, 1e6, size=(32, 8)).astype(np.float32)
        costs = rng.uniform(0.1, 10, size=(8,)).astype(np.float32)
        got = np.asarray(rf_energy(counts, costs))
        np.testing.assert_allclose(
            got, ref.rf_energy_ref(counts, costs), rtol=1e-5
        )

    def test_zero_costs(self):
        counts = np.ones((4, 8), np.float32)
        costs = np.zeros((8,), np.float32)
        assert np.asarray(rf_energy(counts, costs)).sum() == 0.0

    @settings(max_examples=10, deadline=None)
    @given(b=st.integers(1, 32), e=st.integers(1, 12), seed=st.integers(0, 999))
    def test_property_shapes(self, b, e, seed):
        rng = np.random.default_rng(seed)
        counts = rng.uniform(0, 100, size=(b, e)).astype(np.float32)
        costs = rng.uniform(0, 5, size=(e,)).astype(np.float32)
        got = np.asarray(rf_energy(counts, costs))
        assert got.shape == (b,)
        np.testing.assert_allclose(
            got, ref.rf_energy_ref(counts, costs), rtol=1e-5
        )
