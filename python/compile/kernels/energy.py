"""L1 Pallas kernel: RF dynamic-energy evaluation (event-count matvec).

The AccelWattch-style RF energy model is E[b] = sum_e counts[b, e] *
cost[e] over per-benchmark event counts. Tiny, but kept as a Pallas kernel
so the whole compiler-side analysis pipeline lowers into a single HLO
artifact the rust runtime executes. One grid step per row block; counts and
costs live in VMEM (32×8 and 8 f32 — trivially resident).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _energy_kernel(counts_ref, costs_ref, energy_ref):
    counts = counts_ref[...]  # [B, E]
    costs = costs_ref[...]    # [1, E] (kept 2D for TPU-friendly layout)
    energy_ref[...] = jnp.sum(counts * costs, axis=1, keepdims=True)


@jax.jit
def rf_energy(counts, costs):
    """Per-benchmark RF dynamic energy.

    counts: [B, E] f32 event counts; costs: [E] f32 per-event energy.
    Returns [B] f32 total energy.
    """
    b, e = counts.shape
    assert costs.shape == (e,)
    out = pl.pallas_call(
        _energy_kernel,
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        interpret=True,
    )(counts.astype(jnp.float32), costs.reshape(1, e).astype(jnp.float32))
    return out[:, 0]
