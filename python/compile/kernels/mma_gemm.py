"""L1 Pallas kernel: tiled MXU-shaped GEMM — the tensor-core workload.

The paper studies register traffic of Turing HMMA kernels (Deepbench). On
TPU the same insight — *accumulator fragments have near reuse across the
K-loop, A/B fragments stream with far reuse* — is expressed spatially by
the BlockSpec schedule below:

- the C accumulator block (BM×BN f32) stays resident in VMEM across the
  whole K grid dimension (its index_map ignores `k`): this is the "near
  reuse kept in the RF cache" decision, made at compile time;
- the A (BM×BK) and B (BK×BN) blocks stream HBM→VMEM once per K step and
  are never revisited: "far reuse, do not cache".

The Deepbench trace generators in `rust/src/trace/` emit register access
patterns that mirror exactly this allocation (see DESIGN.md §7), so the
simulated SASS stream and this kernel describe the same computation.

VMEM footprint at the default BM=BN=BK=128 (f32): C 64 KB + A 64 KB +
B 64 KB = 192 KB single-buffered (< 1 MB with double buffering), safely
inside a TPU core's ~16 MB VMEM; the MXU sees full 128×128 tiles.

interpret=True for CPU PJRT; numerics validated against ref.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..constants import GEMM_BK, GEMM_BM, GEMM_BN


def _gemm_kernel(x_ref, y_ref, o_ref, *, nk: int):
    """Grid (m, n, k): o block revisited across k, so it acts as the
    VMEM-resident accumulator (near reuse)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def mma_gemm(x, y, *, bm: int = GEMM_BM, bn: int = GEMM_BN, bk: int = GEMM_BK):
    """C = X @ Y with an MXU-shaped block schedule.

    X: [M, K], Y: [K, N], f32 or bf16 (accumulation always f32).
    M, N, K must be multiples of the block sizes.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims differ: {k} vs {k2}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{n},{k}) not divisible by blocks ({bm},{bn},{bk})"
    )
    nk = k // bk
    kernel = functools.partial(_gemm_kernel, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nk),
        in_specs=[
            # A block: row follows i, streams along k (far reuse).
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            # B block: column follows j, streams along k (far reuse).
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        # C block: ignores k — VMEM-resident accumulator (near reuse).
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)
    return out
