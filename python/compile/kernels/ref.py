"""Pure-jnp/numpy oracles for the Pallas kernels — the correctness anchors.

Deliberately written in the most obvious way possible (quadratic scans,
direct matmul) so that pytest comparisons against the kernels are a real
signal, not two copies of the same trick.
"""

import numpy as np

from ..constants import CAP, DEAD, RTHLD, WINDOW


def reuse_distances_ref(ids, pos, rw, window: int = WINDOW, cap: int = CAP):
    """O(W·L·window) scalar reference of kernels.reuse.reuse_distances."""
    ids = np.asarray(ids)
    pos = np.asarray(pos)
    rw = np.asarray(rw)
    w, l = ids.shape
    out = np.full((w, l), -1, dtype=np.int32)
    for r in range(w):
        for i in range(l):
            if ids[r, i] < 0:
                continue
            d = cap
            for j in range(i + 1, min(i + window + 1, l)):
                if ids[r, j] == ids[r, i]:
                    if rw[r, j] == 1:
                        d = min(max(int(pos[r, j]) - int(pos[r, i]), 0), cap)
                    else:
                        d = DEAD  # redefined before any read
                    break
            out[r, i] = d
    return out


def binarize_ref(dist, rthld: int = RTHLD, cap: int = CAP):
    """near=1 / far=0 bit per access; dead values (DEAD) are far; padding
    (-1) stays -1."""
    dist = np.asarray(dist)
    near = ((dist >= 0) & (dist <= rthld)).astype(np.int32)
    out = np.where(dist == DEAD, 0, near)
    return np.where(dist == -1, -1, out)


def histogram_ref(dist):
    """Fig-1 buckets over valid reuses: [d<=1, d==2, d==3, 4<=d<=10, d>10].

    d==0 (reuse within the same dynamic instruction) folds into the first
    bucket. Accesses with no observed reuse inside the window (dist == CAP)
    count in the >10 bucket (any such reuse is certainly >10 instructions
    away); dead values (DEAD) and padding are excluded — the paper's Fig 1
    plots values "used at least once".
    """
    dist = np.asarray(dist)
    valid = dist >= 0
    d = dist[valid]
    return np.array(
        [
            int((d <= 1).sum()),
            int((d == 2).sum()),
            int((d == 3).sum()),
            int(((d >= 4) & (d <= 10)).sum()),
            int((d > 10).sum()),
        ],
        dtype=np.int32,
    )


def gemm_ref(x, y):
    """Direct f32 matmul reference."""
    return np.matmul(
        np.asarray(x, dtype=np.float32), np.asarray(y, dtype=np.float32)
    )


def rf_energy_ref(counts, costs):
    """E[b] = sum_e counts[b, e] * costs[e]."""
    return (np.asarray(counts, np.float32) * np.asarray(costs, np.float32)).sum(
        axis=1
    )
