"""L1 Pallas kernel: forward register reuse-distance annotation.

This is the compute hot-spot of the paper's *compiler* contribution
(§III-A): given a per-warp stream of register accesses (one row per
profiled warp), compute for every access the distance — in dynamic
*instructions* — to the next access of the same register, then binarise it
against RTHLD into the near/far bit the hardware consumes.

Value semantics: a reuse is the next *read* of the register. If the first
following access is a *write* (redefinition), the current value is dead —
reported as DEAD and treated as far by the annotation (caching a dying
value is pure pollution; the paper's Fig 1 likewise counts only "register
values used at least once").

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's pass runs
over SASS traces on a CPU; here it is expressed as a data-parallel TPU
kernel. Each grid step owns one warp's access row resident in VMEM
(3 × TRACE_LEN × 4B = 24 KB per row — far below VMEM capacity) and performs
a windowed forward scan: WINDOW shifted compares instead of an O(L²)
all-pairs table, which would need L²×4B = 16 MB and not fit VMEM. Any reuse
beyond WINDOW accesses is ≥ RTHLD instructions away and therefore *far*, so
capping preserves the binary answer exactly.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are validated against ref.py by pytest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..constants import CAP, DEAD, WINDOW


def _reuse_kernel(ids_ref, pos_ref, rw_ref, dist_ref, *, window: int, cap: int):
    """One warp row: forward reuse distance per access.

    ids_ref:  [1, L] int32 register id per access, -1 for padding.
    pos_ref:  [1, L] int32 dynamic-instruction index of each access.
    rw_ref:   [1, L] int32 access type (1 = read, 0 = write).
    dist_ref: [1, L] int32 out; distance to the next read of the same
              register; DEAD if the register is redefined first; cap if no
              access within `window`; -1 on padding lanes.
    """
    ids = ids_ref[0, :]
    pos = pos_ref[0, :]
    rw = rw_ref[0, :]
    n = ids.shape[0]
    lane = jax.lax.iota(jnp.int32, n)

    best = jnp.full((n,), cap, dtype=jnp.int32)
    found = jnp.zeros((n,), dtype=jnp.bool_)
    # Static unroll: `window` shifted compares. Each iteration is a pure
    # vector op over the row; on TPU this maps onto the VPU with the row in
    # VMEM, no gathers, no data-dependent control flow.
    for k in range(1, window + 1):
        ids_k = jnp.roll(ids, -k)
        pos_k = jnp.roll(pos, -k)
        rw_k = jnp.roll(rw, -k)
        in_row = lane + k < n
        match = in_row & (ids_k == ids) & (ids >= 0)
        d_read = jnp.clip(pos_k - pos, 0, cap).astype(jnp.int32)
        d = jnp.where(rw_k == 1, d_read, DEAD)  # write first -> value dead
        best = jnp.where(match & ~found, d, best)
        found = found | match
    dist_ref[0, :] = jnp.where(ids >= 0, best, -1)


@functools.partial(jax.jit, static_argnames=("window", "cap"))
def reuse_distances(ids, pos, rw, *, window: int = WINDOW, cap: int = CAP):
    """Forward reuse distances for a [W, L] batch of access streams.

    Grid = one program per warp row; the BlockSpec pins a full row in VMEM.
    Returns [W, L] int32 distances (cap = none-within-window, DEAD = value
    redefined before any read, -1 = padding).
    """
    w, l = ids.shape
    assert pos.shape == (w, l) and rw.shape == (w, l)
    kernel = functools.partial(_reuse_kernel, window=window, cap=cap)
    return pl.pallas_call(
        kernel,
        grid=(w,),
        in_specs=[
            pl.BlockSpec((1, l), lambda i: (i, 0)),
            pl.BlockSpec((1, l), lambda i: (i, 0)),
            pl.BlockSpec((1, l), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, l), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((w, l), jnp.int32),
        interpret=True,
    )(ids.astype(jnp.int32), pos.astype(jnp.int32), rw.astype(jnp.int32))
