"""L2 JAX model: the compiler-side analysis pipeline, composed from the L1
Pallas kernels. These are the functions aot.py lowers to HLO text for the
rust runtime; Python never runs after `make artifacts`.

Entry points (fixed AOT shapes in constants.py):

  annotate(ids, pos)      -> (dist, near, hist)   — reuse annotation (§III-A)
  energy(counts, costs)   -> (energy, normalized) — AccelWattch-style RF model
  gemm(x, y)              -> (c,)                 — tensor-core workload
"""

import jax.numpy as jnp

from .constants import RTHLD
from .kernels.energy import rf_energy
from .kernels.mma_gemm import mma_gemm
from .kernels.reuse import reuse_distances


def annotate(ids, pos, rw):
    """Full reuse annotation of a profiled trace batch.

    ids, pos, rw: [W, L] int32 (id < 0 = padding; rw 1 = read, 0 = write).
    Returns:
      dist: [W, L] int32 forward reuse distance (CAP-capped, DEAD = value
            redefined before read, -1 pad),
      near: [W, L] int32 near(1)/far(0) bit (dead = far, -1 pad),
      hist: [5] int32 Fig-1 buckets (d<=1, ==2, ==3, 4..10, >10) over all
            warps, live values only.
    """
    dist = reuse_distances(ids, pos, rw)
    valid = dist >= 0  # excludes padding (-1) and dead values (DEAD)
    pad = ids < 0
    near = jnp.where(valid, (dist <= RTHLD).astype(jnp.int32), 0)
    near = jnp.where(pad, -1, near)
    d = jnp.where(valid, dist, 0)
    hist = jnp.stack(
        [
            jnp.sum(valid & (d <= 1)),
            jnp.sum(valid & (d == 2)),
            jnp.sum(valid & (d == 3)),
            jnp.sum(valid & (d >= 4) & (d <= 10)),
            jnp.sum(valid & (d > 10)),
        ]
    ).astype(jnp.int32)
    return dist, near, hist


def energy(counts, costs):
    """RF dynamic energy per benchmark and values normalized to row 0.

    counts: [B, E] f32, costs: [E] f32. Row 0 is by convention the baseline
    configuration; `normalized[b] = energy[b] / energy[0]`.
    """
    e = rf_energy(counts, costs)
    denom = jnp.where(e[0] != 0.0, e[0], 1.0)
    return e, e / denom


def gemm(x, y):
    """Tensor-core workload GEMM (tuple-wrapped for uniform AOT plumbing)."""
    return (mma_gemm(x, y),)
