"""Shared constants between the compile path (L1/L2) and the rust side.

The rust runtime mirrors these in `rust/src/runtime/manifest.rs`; aot.py also
emits `artifacts/manifest.txt` so the two can never silently diverge.
"""

# --- Reuse-distance annotation (paper §III-A) -------------------------------
# Binary approximation threshold: reuse distances (in dynamic instructions)
# strictly greater than RTHLD are "far", otherwise "near". The paper found 12
# empirically best for its benchmark set.
RTHLD = 12

# Forward-scan window of the Pallas kernel, in *accesses*: a reuse farther
# than WINDOW accesses ahead is reported as CAP. Worst case is tensor-core
# code at 8 operands/instruction: 96 accesses = 12 instructions = RTHLD, so
# a capped distance is always genuinely "far" and the binary answer is
# exact.
WINDOW = 96

# Distance value meaning "no reuse found within WINDOW" (always far).
CAP = 255

# Marker for a value that is redefined (written) before any read: dead, no
# reuse. Treated as far by the annotation; excluded from Fig-1 histograms
# ("register values used at least once").
DEAD = -2

# AOT shapes for the reuse-annotation artifact: [PROFILE_WARPS, TRACE_LEN]
# padded access streams (id < 0 = padding).
PROFILE_WARPS = 8
TRACE_LEN = 2048

# Fig-1 histogram buckets over reuse distance d (instructions):
# d==1, d==2, d==3, 4<=d<=10, d>10   (paper's Fig. 1 x-axis).
HIST_BUCKETS = 5

# --- RF dynamic-energy model (paper §V, AccelWattch-derived) ----------------
# Event kinds, in artifact column order. Mirrored by rust energy::EventKind.
ENERGY_EVENTS = [
    "bank_read",      # read of one 128B operand from an RF bank
    "bank_write",     # write of one 128B operand to an RF bank
    "ccu_read",       # operand served from a CCU/BOC/RFC cache entry
    "ccu_write",      # operand written into a cache entry
    "xbar_transfer",  # crossbar traversal bank -> collector
    "arbiter_op",     # arbiter decision
    "oct_op",         # collector bookkeeping (tag check, OCT update)
    "leak_proxy",     # per-cycle structure-size proxy (relative)
]
ENERGY_NEVENTS = len(ENERGY_EVENTS)
ENERGY_ROWS = 32  # max benchmarks per energy-model batch

# --- Tensor-core workload GEMM (Deepbench stand-in) --------------------------
GEMM_M = 256
GEMM_N = 256
GEMM_K = 256
GEMM_BM = 128
GEMM_BN = 128
GEMM_BK = 128
