"""AOT pipeline: lower the L2 entry points to HLO *text* artifacts.

HLO text — NOT `lowered.compile()` / serialized HloModuleProto — is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which the rust side's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`);
the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/load_hlo and its README).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Also emits `manifest.txt` (artifact name, entry shapes, constants) that the
rust runtime parses to validate it is feeding the right tensors.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .constants import (
    ENERGY_NEVENTS,
    ENERGY_ROWS,
    GEMM_K,
    GEMM_M,
    GEMM_N,
    HIST_BUCKETS,
    PROFILE_WARPS,
    RTHLD,
    TRACE_LEN,
    WINDOW,
    CAP,
)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


ARTIFACTS = {
    # name -> (fn, arg specs, human-readable signature for the manifest)
    "reuse_annotate": (
        model.annotate,
        (
            _spec((PROFILE_WARPS, TRACE_LEN), jnp.int32),
            _spec((PROFILE_WARPS, TRACE_LEN), jnp.int32),
            _spec((PROFILE_WARPS, TRACE_LEN), jnp.int32),
        ),
        f"ids:i32[{PROFILE_WARPS},{TRACE_LEN}] pos:i32[{PROFILE_WARPS},{TRACE_LEN}]"
        f" rw:i32[{PROFILE_WARPS},{TRACE_LEN}]"
        f" -> dist:i32[{PROFILE_WARPS},{TRACE_LEN}]"
        f" near:i32[{PROFILE_WARPS},{TRACE_LEN}] hist:i32[{HIST_BUCKETS}]",
    ),
    "rf_energy": (
        model.energy,
        (
            _spec((ENERGY_ROWS, ENERGY_NEVENTS), jnp.float32),
            _spec((ENERGY_NEVENTS,), jnp.float32),
        ),
        f"counts:f32[{ENERGY_ROWS},{ENERGY_NEVENTS}] costs:f32[{ENERGY_NEVENTS}]"
        f" -> energy:f32[{ENERGY_ROWS}] normalized:f32[{ENERGY_ROWS}]",
    ),
    "mma_gemm": (
        model.gemm,
        (
            _spec((GEMM_M, GEMM_K), jnp.float32),
            _spec((GEMM_K, GEMM_N), jnp.float32),
        ),
        f"x:f32[{GEMM_M},{GEMM_K}] y:f32[{GEMM_K},{GEMM_N}]"
        f" -> c:f32[{GEMM_M},{GEMM_N}]",
    ),
}


def build(out_dir: str, only=None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = [
        "# malekeh AOT artifact manifest (parsed by rust/src/runtime/manifest.rs)",
        f"rthld={RTHLD}",
        f"window={WINDOW}",
        f"cap={CAP}",
        f"profile_warps={PROFILE_WARPS}",
        f"trace_len={TRACE_LEN}",
        f"hist_buckets={HIST_BUCKETS}",
        f"energy_rows={ENERGY_ROWS}",
        f"energy_events={ENERGY_NEVENTS}",
    ]
    for name, (fn, specs, sig) in ARTIFACTS.items():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"artifact={name}.hlo.txt :: {sig}")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {os.path.join(out_dir, 'manifest.txt')}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", default=None, choices=list(ARTIFACTS))
    # kept for the scaffold Makefile's `--out ../artifacts/model.hlo.txt` shape
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = args.out_dir if args.out is None else os.path.dirname(args.out)
    build(out_dir, args.only)


if __name__ == "__main__":
    main()
