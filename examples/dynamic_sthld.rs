//! The dynamic STHLD algorithm in action (paper §IV-B3, Figs 8/9).
//!
//! Runs the phase-changing synthetic workload with (a) a sweep of static
//! STHLD values and (b) the dynamic FSM, showing that the FSM tracks the
//! knee without per-application tuning.
//!
//!     cargo run --release --example dynamic_sthld

use malekeh::config::{GpuConfig, Scheme, SthldMode};
use malekeh::harness::Table;
use malekeh::sim::run_benchmark;

fn cfg_with(sthld: SthldMode) -> GpuConfig {
    let mut c = GpuConfig::table1_baseline().with_scheme(Scheme::MALEKEH);
    c.num_sms = 1;
    c.sthld = sthld;
    c.sthld_interval = 2_000;
    c
}

fn main() {
    let bench = "synthetic_phases";

    let mut t = Table::new(
        "static STHLD sweep vs dynamic (synthetic_phases)",
        &["sthld", "IPC", "hit_ratio", "waiting_stalls"],
    );
    let mut best_static = (0u32, 0f64);
    for s in [0u32, 1, 2, 4, 8, 16, 32] {
        let stats = run_benchmark(&cfg_with(SthldMode::Static(s)), bench, 2);
        if stats.ipc() > best_static.1 {
            best_static = (s, stats.ipc());
        }
        t.row(vec![
            format!("{s}"),
            format!("{:.3}", stats.ipc()),
            format!("{:.3}", stats.rf_hit_ratio()),
            format!("{}", stats.waiting_stalls),
        ]);
    }
    let dyn_stats = run_benchmark(&cfg_with(SthldMode::Dynamic), bench, 2);
    t.row(vec![
        "dynamic".into(),
        format!("{:.3}", dyn_stats.ipc()),
        format!("{:.3}", dyn_stats.rf_hit_ratio()),
        format!("{}", dyn_stats.waiting_stalls),
    ]);
    t.print();

    println!(
        "best static: STHLD={} (IPC {:.3}); dynamic reaches IPC {:.3} with hit {:.3}",
        best_static.0,
        best_static.1,
        dyn_stats.ipc(),
        dyn_stats.rf_hit_ratio()
    );

    // the walk itself (Fig 9)
    let mut walk = Table::new(
        "dynamic walk: STHLD per 2000-cycle interval",
        &["interval", "sthld", "interval_ipc"],
    );
    for (i, (s, ipc)) in dyn_stats
        .sthld_trace
        .iter()
        .zip(dyn_stats.interval_ipc.iter())
        .enumerate()
        .take(30)
    {
        walk.row(vec![format!("{i}"), format!("{s}"), format!("{ipc:.3}")]);
    }
    walk.print();
}
