//! Register an ad-hoc RF-cache policy at runtime and run it end to end —
//! the scheme registry's extension point (`docs/ARCHITECTURE.md` §Policy
//! layer), exercised without touching a single simulator file.
//!
//! The policy here rides the CCU hardware under GTO issue but evicts a
//! *uniformly random* unlocked entry, drawing from the sub-core's seeded
//! `util::Rng` — so even "random" replacement is fully deterministic and
//! fingerprint-stable, as the run below demonstrates.
//!
//! Run: `cargo run --release --example custom_policy [bench]`

use malekeh::config::{GpuConfig, Scheme};
use malekeh::isa::Instruction;
use malekeh::sim::collector::{AllocResult, CacheTable};
use malekeh::sim::exec::WbEvent;
use malekeh::sim::policy::{
    ccu_allocate, ccu_capture, free_unit_reservoir, register, CachePolicy, CollectorChoice,
    PolicyCtx, PolicyMeta,
};
use malekeh::sim::run_benchmark;
use malekeh::util::Rng;

/// Evict a uniformly random unlocked entry (one RNG draw per eviction).
///
/// Written in the policy layer's allocation-free idiom (see
/// `sim::policy` "Allocation contract"): count the candidates, draw one
/// ordinal, resolve it — never collect a candidate `Vec` on the hot path.
/// The RNG sees the identical single `below(count)` draw a collecting
/// version would make, so the choice is the same bit-for-bit.
fn random_victim(ct: &CacheTable, rng: &mut Rng) -> Option<usize> {
    let unlocked = ct.entries().iter().filter(|e| !e.locked).count();
    if unlocked == 0 {
        return None;
    }
    let k = rng.below(unlocked);
    ct.entries()
        .iter()
        .enumerate()
        .filter(|(_, e)| !e.locked)
        .nth(k)
        .map(|(i, _)| i)
}

/// CCU hardware + GTO + random replacement, defined entirely out of tree.
struct RandomReplPolicy {
    ct_entries: usize,
}

impl CachePolicy for RandomReplPolicy {
    fn caching(&self) -> bool {
        true
    }

    fn cache_entries_per_collector(&self) -> f64 {
        self.ct_entries as f64
    }

    fn select_collector(&mut self, ctx: &mut PolicyCtx, _warp: u8) -> CollectorChoice {
        match free_unit_reservoir(ctx.collectors, ctx.rng) {
            Some(ci) => CollectorChoice::Unit(ci),
            None => {
                ctx.stats.collector_full_stalls += 1;
                CollectorChoice::StallCycle { waiting: false }
            }
        }
    }

    fn allocate(
        &mut self,
        ctx: &mut PolicyCtx,
        ci: usize,
        warp: u8,
        instr: &Instruction,
        now: u64,
    ) -> AllocResult {
        ccu_allocate(ctx, ci, warp, instr, now, &mut random_victim)
    }

    fn capture_writeback(
        &mut self,
        ctx: &mut PolicyCtx,
        ev: &WbEvent,
        reg: u8,
        near: bool,
        port_free: bool,
    ) -> bool {
        ccu_capture(ctx, ev, reg, near, port_free, &mut random_victim, true)
    }
}

fn main() {
    let bench = std::env::args().nth(1).unwrap_or_else(|| "kmeans".into());

    // 1. register: the name is now a first-class scheme everywhere
    let scheme = register(
        PolicyMeta {
            name: "random_repl",
            summary: "CCU hardware under GTO + seeded random replacement (example)",
            private_per_warp: false,
            two_level: false,
            fig17_sweep: false,
        },
        |cfg| Box::new(RandomReplPolicy { ct_entries: cfg.ct_entries }),
    )
    .expect("name is free");
    assert_eq!(Scheme::from_name("random_repl"), Some(scheme));
    assert!(Scheme::all().contains(&scheme), "registry lists the new policy");

    // 2. run it exactly like a built-in scheme
    let mut cfg = GpuConfig::table1_baseline().with_scheme(scheme);
    cfg.num_sms = 1;
    let stats = run_benchmark(&cfg, &bench, 2);
    let again = run_benchmark(&cfg, &bench, 2);

    println!("benchmark            {bench}");
    println!("scheme               {} ({})", scheme, scheme.meta().summary);
    println!("cycles               {}", stats.cycles);
    println!("instructions         {}", stats.instructions);
    println!("RF cache hit ratio   {:.3}", stats.rf_hit_ratio());
    println!("cache writes         {}", stats.rf_cache_writes);
    println!("stats fingerprint    {:016x}", stats.fingerprint());
    assert_eq!(
        stats.fingerprint(),
        again.fingerprint(),
        "seeded random replacement must be run-to-run deterministic"
    );
    println!("rerun fingerprint    identical (deterministic by construction)");

    // 3. compare against the built-ins on the same benchmark
    for s in [Scheme::MALEKEH, Scheme::MALEKEH_TRADITIONAL, Scheme::FIFO, Scheme::BELADY] {
        let mut c = GpuConfig::table1_baseline().with_scheme(s);
        c.num_sms = 1;
        let r = run_benchmark(&c, &bench, 2);
        println!("  vs {:20} hit ratio {:.3}", s.name(), r.rf_hit_ratio());
    }
}
