//! RF dynamic-energy deep dive: per-event breakdown from the rust model,
//! cross-checked against the AOT `rf_energy` artifact (the L1 Pallas
//! matvec) through the PJRT runtime.
//!
//!     cargo run --release --example energy_report [bench]

use malekeh::config::{GpuConfig, Scheme};
use malekeh::energy::{EnergyModel, EventKind, EVENT_NAMES, NEVENTS};
use malekeh::harness::Table;
use malekeh::sim::run_benchmark;

fn main() {
    let bench = std::env::args().nth(1).unwrap_or_else(|| "rnn_t2".to_string());
    let schemes = [Scheme::BASELINE, Scheme::MALEKEH, Scheme::BOW];

    let mut per_scheme = Vec::new();
    for s in schemes {
        let mut cfg = GpuConfig::table1_baseline().with_scheme(s);
        cfg.num_sms = 2;
        let stats = run_benchmark(&cfg, &bench, 2);
        let model = EnergyModel::for_config(&cfg);
        per_scheme.push((s, stats, model));
    }

    // per-event breakdown table
    let mut t = Table::new(
        &format!("RF energy breakdown for `{bench}` (relative units)"),
        &["event", "baseline", "malekeh", "bow"],
    );
    for ev in 0..NEVENTS {
        let kind = [
            EventKind::BankRead,
            EventKind::BankWrite,
            EventKind::CcuRead,
            EventKind::CcuWrite,
            EventKind::XbarTransfer,
            EventKind::ArbiterOp,
            EventKind::OctOp,
            EventKind::LeakProxy,
        ][ev];
        let vals: Vec<f64> = per_scheme
            .iter()
            .map(|(_, st, m)| st.energy.get(kind) as f64 * m.costs()[ev])
            .collect();
        t.row_f(EVENT_NAMES[ev], &vals, 0);
    }
    let totals: Vec<f64> = per_scheme
        .iter()
        .map(|(_, st, m)| m.total(&st.energy))
        .collect();
    t.row_f("TOTAL", &totals, 0);
    t.print();
    println!(
        "normalised: baseline 1.000, malekeh {:.3}, bow {:.3}",
        totals[1] / totals[0],
        totals[2] / totals[0]
    );

    // cross-check through the AOT artifact
    match malekeh::runtime::Runtime::open_default() {
        Ok(mut rt) => {
            let rows = rt.manifest.energy_rows;
            let mut counts = vec![0f32; rows * NEVENTS];
            for (i, (_, st, _)) in per_scheme.iter().enumerate() {
                counts[i * NEVENTS..(i + 1) * NEVENTS]
                    .copy_from_slice(&st.energy.as_f32_row());
            }
            // artifact applies ONE cost vector; evaluate with each scheme's
            // costs and read back its own row
            let mut artifact_totals = Vec::new();
            for (i, (_, _, model)) in per_scheme.iter().enumerate() {
                let (energy, _) = rt
                    .rf_energy(&counts, &model.costs_f32())
                    .expect("rf_energy artifact");
                artifact_totals.push(energy[i] as f64);
            }
            println!("\nPJRT rf_energy artifact cross-check:");
            for ((s, _, _), (rust_t, art_t)) in per_scheme
                .iter()
                .zip(totals.iter().zip(artifact_totals.iter()))
            {
                let rel = (rust_t - art_t).abs() / rust_t.max(1.0);
                println!("  {s:<10} rust {rust_t:.0} vs artifact {art_t:.0} (rel err {rel:.2e})");
                assert!(rel < 1e-3, "artifact/model divergence");
            }
        }
        Err(e) => println!("(artifacts not built; skipping PJRT cross-check: {e})"),
    }
}
