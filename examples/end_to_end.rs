//! End-to-end driver: the full three-layer stack on a real small workload.
//!
//! Pipeline (all layers composing, nothing mocked):
//!   1. generate Table II workload traces (one Rodinia + one Deepbench);
//!   2. run the *compiler pass through the AOT Pallas artifact*: flatten
//!      access streams, execute `reuse_annotate.hlo.txt` on the PJRT CPU
//!      client, vote + binarise, and write the near/far bits into the
//!      traces (the rust engine only cross-checks — the annotation used by
//!      the simulation comes from the artifact);
//!   3. simulate the Table I GPU under baseline and Malekeh;
//!   4. report the paper's headline metrics.
//!
//!     cargo run --release --example end_to_end [--full]
//!
//! The run is recorded in docs/EXPERIMENTS.md §End-to-end.

use malekeh::compiler;
use malekeh::config::{GpuConfig, Scheme};
use malekeh::energy::EnergyModel;
use malekeh::runtime::Runtime;
use malekeh::sim::Simulator;
use malekeh::trace::KernelTrace;

/// Annotate `trace` using the AOT artifact: profile `w` warps through the
/// PJRT executable, then apply the votes to every warp. Returns the
/// near-bit fraction among profiled accesses.
fn annotate_via_artifact(rt: &mut Runtime, trace: &mut KernelTrace, rthld: u32) -> f64 {
    let w = rt.manifest.profile_warps;
    let l = rt.manifest.trace_len;
    let (ids, pos, rw) = trace.access_streams(w, l);
    let (dist, near, _hist) = rt.annotate(&ids, &pos, &rw).expect("pjrt annotate");

    // cross-check a row against the rust engine (belt and braces)
    let want = compiler::windowed_reuse_distances(
        &ids[..l],
        &pos[..l],
        &rw[..l],
        compiler::WINDOW,
        compiler::CAP,
    );
    assert_eq!(&dist[..l], &want[..], "artifact/rust parity");

    // vote per static operand from the artifact's distances, then annotate.
    // (compiler::profile uses the rust engine; to keep the artifact on the
    // critical path we reconstruct the same votes from `dist`.)
    let mut votes: std::collections::HashMap<(u8, u8, bool, u8), (u32, u32)> =
        std::collections::HashMap::new();
    for row in 0..w.min(trace.warps.len()) {
        let mut k = 0usize;
        'outer: for instr in &trace.warps[row] {
            for (slot, &r) in instr.sources().iter().enumerate() {
                if k >= l {
                    break 'outer;
                }
                let d = dist[row * l + k];
                if d != -1 {
                    let e = votes.entry((instr.op as u8, slot as u8, false, r)).or_insert((0, 0));
                    if d >= 0 && d as u32 <= rthld {
                        e.0 += 1;
                    } else {
                        e.1 += 1;
                    }
                }
                k += 1;
            }
            for (slot, &r) in instr.dests().iter().enumerate() {
                if k >= l {
                    break 'outer;
                }
                let d = dist[row * l + k];
                if d != -1 {
                    let e = votes.entry((instr.op as u8, slot as u8, true, r)).or_insert((0, 0));
                    if d >= 0 && d as u32 <= rthld {
                        e.0 += 1;
                    } else {
                        e.1 += 1;
                    }
                }
                k += 1;
            }
        }
    }
    for warp in &mut trace.warps {
        for instr in warp.iter_mut() {
            for slot in 0..instr.nsrc as usize {
                let key = (instr.op as u8, slot as u8, false, instr.sources()[slot]);
                let near = votes.get(&key).map(|(n, f)| n >= f).unwrap_or(false);
                instr.set_src_near(slot, near);
            }
            for slot in 0..instr.ndst as usize {
                let key = (instr.op as u8, slot as u8, true, instr.dests()[slot]);
                let near = votes.get(&key).map(|(n, f)| n >= f).unwrap_or(false);
                instr.set_dst_near(slot, near);
            }
        }
    }
    let n_near = near.iter().filter(|&&x| x == 1).count();
    let n_valid = near.iter().filter(|&&x| x >= 0).count();
    n_near as f64 / n_valid.max(1) as f64
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let num_sms = if full { 10 } else { 2 };

    println!("=== end-to-end: L1 Pallas artifact -> L3 rust simulator ===\n");
    let mut rt = Runtime::open_default().expect(
        "artifacts missing — run `make artifacts` first (python only runs there)",
    );
    println!(
        "artifacts: {:?} (rthld={}, window={})",
        rt.manifest.artifacts, rt.manifest.rthld, rt.manifest.window
    );

    let mut grand: Vec<(String, f64, f64, f64, f64)> = Vec::new();
    for bench_name in ["srad_v1", "rnn_i2"] {
        let bench = malekeh::trace::find(bench_name).unwrap();
        let mut cfg = GpuConfig::table1_baseline();
        cfg.num_sms = num_sms;
        let nwarps = cfg.num_sms * cfg.warps_per_sm;

        // 1-2: generate + annotate through the artifact
        let mut trace = KernelTrace::generate(bench, nwarps, cfg.seed);
        let t0 = std::time::Instant::now();
        let near_frac = annotate_via_artifact(&mut rt, &mut trace, cfg.rthld);
        println!(
            "\n[{bench_name}] compiler pass via PJRT artifact: {:.1} ms, near fraction {:.3}",
            t0.elapsed().as_secs_f64() * 1e3,
            near_frac
        );

        // 3: simulate baseline + malekeh on the SAME annotated trace
        let t0 = std::time::Instant::now();
        let base = Simulator::new(&cfg, &trace).run();
        let mal_cfg = cfg.clone().with_scheme(Scheme::MALEKEH);
        let mal = Simulator::new(&mal_cfg, &trace).run();
        println!(
            "[{bench_name}] simulated {} + {} instrs in {:.1}s",
            base.instructions,
            mal.instructions,
            t0.elapsed().as_secs_f64()
        );

        // 4: headline metrics
        let be = EnergyModel::for_config(&cfg).total(&base.energy);
        let me = EnergyModel::for_config(&mal_cfg).total(&mal.energy);
        let d_ipc = mal.ipc() / base.ipc() - 1.0;
        let hit = mal.rf_hit_ratio();
        let bank_red = mal.bank_read_reduction_vs(&base);
        let d_e = me / be - 1.0;
        println!(
            "[{bench_name}] IPC {:+.1}%  |  hit {:.1}%  |  bank reads {:.1}% fewer  |  RF energy {:+.1}%",
            d_ipc * 100.0,
            hit * 100.0,
            bank_red * 100.0,
            d_e * 100.0
        );
        grand.push((bench_name.to_string(), d_ipc, hit, bank_red, d_e));
    }

    println!("\n=== summary (paper 10-SM averages: +6.1% IPC, 46.4% hit, -28.3% energy) ===");
    for (b, di, h, br, de) in &grand {
        println!(
            "  {b:<10} IPC {:+.1}%  hit {:.1}%  bank-reads -{:.1}%  energy {:+.1}%",
            di * 100.0,
            h * 100.0,
            br * 100.0,
            de * 100.0
        );
    }
    // the run must demonstrate the mechanism actually engaging
    assert!(
        grand.iter().all(|g| g.2 > 0.15),
        "RF cache hit ratio suspiciously low — mechanism not engaging"
    );
    println!("\nend_to_end OK");
}
