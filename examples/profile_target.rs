//! Profiling helper for the §Perf pass: a fixed Malekeh/kmeans workload
//! repeated 5x, used as the `perf record` target (protocol and known hot
//! symbols: docs/EXPERIMENTS.md §Profiling).
use malekeh::config::{GpuConfig, Scheme};
use malekeh::sim::run_benchmark;
fn main() {
    let mut cfg = GpuConfig::table1_baseline().with_scheme(Scheme::MALEKEH);
    cfg.num_sms = 1;
    for _ in 0..5 { run_benchmark(&cfg, "kmeans", 2); }
}
