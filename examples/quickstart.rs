//! Quickstart: simulate one benchmark under the baseline and under Malekeh,
//! and print the comparison the paper is about.
//!
//!     cargo run --release --example quickstart [bench]

use malekeh::config::{GpuConfig, Scheme};
use malekeh::energy::EnergyModel;
use malekeh::sim::run_benchmark;

fn main() {
    let bench = std::env::args().nth(1).unwrap_or_else(|| "kmeans".to_string());

    // Table I baseline config, scaled to 2 SMs for a fast first run.
    let mut base_cfg = GpuConfig::table1_baseline();
    base_cfg.num_sms = 2;
    let mal_cfg = base_cfg.clone().with_scheme(Scheme::MALEKEH);

    println!("simulating `{bench}` on {} SMs...\n", base_cfg.num_sms);
    let base = run_benchmark(&base_cfg, &bench, 2);
    let mal = run_benchmark(&mal_cfg, &bench, 2);

    let base_e = EnergyModel::for_config(&base_cfg).total(&base.energy);
    let mal_e = EnergyModel::for_config(&mal_cfg).total(&mal.energy);

    println!("{:<28}{:>14}{:>14}", "", "baseline", "malekeh");
    println!("{:<28}{:>14}{:>14}", "cycles", base.cycles, mal.cycles);
    println!(
        "{:<28}{:>14.3}{:>14.3}",
        "IPC",
        base.ipc(),
        mal.ipc()
    );
    println!(
        "{:<28}{:>14}{:>14}",
        "RF bank reads", base.rf_bank_reads, mal.rf_bank_reads
    );
    println!(
        "{:<28}{:>14.1}{:>14.1}",
        "RF cache hit ratio (%)",
        base.rf_hit_ratio() * 100.0,
        mal.rf_hit_ratio() * 100.0
    );
    println!(
        "{:<28}{:>14.0}{:>14.0}",
        "RF dynamic energy (rel)", base_e, mal_e
    );
    println!();
    println!(
        "Malekeh: {:+.1}% IPC, {:.1}% of bank reads eliminated, {:+.1}% RF energy",
        (mal.ipc() / base.ipc() - 1.0) * 100.0,
        mal.bank_read_reduction_vs(&base) * 100.0,
        (mal_e / base_e - 1.0) * 100.0
    );
    println!("(paper, 10-SM average over Table II: +6.1% IPC, 46.4% fewer bank reads, -28.3% energy)");
}
