//! Record -> transform -> replay: the trace I/O workflow end to end.
//!
//! Records a builtin benchmark to a `.mtrace` file, derives a 1-in-4 warp
//! subsample with `trace::io::transform`, replays both through the
//! simulator, and compares IPC / RF-hit-ratio — demonstrating that (a) a
//! recorded trace replays bit-identically and (b) transforms give smaller
//! scenario variants without regenerating anything.
//!
//!     cargo run --release --example replay_trace [bench]

use malekeh::config::{GpuConfig, Scheme};
use malekeh::sim::run_workload;
use malekeh::stats::Stats;
use malekeh::trace::io::{self, Transform};
use malekeh::trace::{find, KernelTrace, Workload};

fn main() {
    let bench_name = std::env::args().nth(1).unwrap_or_else(|| "kmeans".into());
    let bench =
        find(&bench_name).unwrap_or_else(|| panic!("unknown bench {bench_name}"));

    let mut cfg = GpuConfig::table1_baseline().with_scheme(Scheme::MALEKEH);
    cfg.num_sms = 2;
    let nwarps = cfg.num_sms * cfg.warps_per_sm;

    // 1. record: generate the builtin trace and serialise it
    let full = KernelTrace::generate(bench, nwarps, cfg.seed);
    let dir = std::env::temp_dir();
    let full_path = dir.join(format!("malekeh_replay_{bench_name}_full.mtrace"));
    io::write_path(&full_path, &full).expect("write full trace");

    // 2. transform: keep one warp in four
    let quarter = Transform::WarpSubsample { keep_one_in: 4 }.apply(&full);
    let quarter_path = dir.join(format!("malekeh_replay_{bench_name}_q4.mtrace"));
    io::write_path(&quarter_path, &quarter).expect("write subsampled trace");

    // 3. replay: builtin generator vs full recording vs 1/4 subsample
    println!(
        "replaying `{bench_name}` under {} ({} warps full, {} subsampled)...\n",
        cfg.scheme,
        full.warps.len(),
        quarter.warps.len()
    );
    let direct = run_workload(&cfg, &Workload::builtin(&bench_name), 2).unwrap();
    let replay = run_workload(&cfg, &Workload::trace_file(&full_path), 2).unwrap();
    let sub = run_workload(&cfg, &Workload::trace_file(&quarter_path), 2).unwrap();

    let row = |label: &str, s: &Stats| {
        println!(
            "{label:<22}{:>12}{:>10.3}{:>10.1}%{:>20x}",
            s.instructions,
            s.ipc(),
            s.rf_hit_ratio() * 100.0,
            s.fingerprint()
        );
    };
    println!(
        "{:<22}{:>12}{:>10}{:>11}{:>20}",
        "workload", "instrs", "IPC", "RF hit", "fingerprint"
    );
    row("builtin generator", &direct);
    row("recorded replay", &replay);
    row("1/4 warp subsample", &sub);

    assert_eq!(
        direct.fingerprint(),
        replay.fingerprint(),
        "recorded replay must be bit-identical to the builtin run"
    );
    println!("\nrecorded replay is bit-identical to the builtin run \u{2713}");
    println!(
        "subsample: {:.1}% of the instructions at {:+.1}% RF hit ratio delta",
        sub.instructions as f64 / direct.instructions.max(1) as f64 * 100.0,
        (sub.rf_hit_ratio() - direct.rf_hit_ratio()) * 100.0
    );

    std::fs::remove_file(&full_path).ok();
    std::fs::remove_file(&quarter_path).ok();
}
