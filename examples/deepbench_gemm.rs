//! Deepbench tensor-core scenario: the workload the paper's Fig 1 motivates.
//!
//! 1. Executes the AOT `mma_gemm` artifact (the L1 Pallas kernel the
//!    Deepbench trace generators model) through the PJRT runtime and checks
//!    its numerics against a plain rust matmul.
//! 2. Simulates the Deepbench suite under baseline / Malekeh / BOW /
//!    Malekeh_PR and prints the tensor-core columns of Figs 12/13.
//!
//!     cargo run --release --example deepbench_gemm

use malekeh::config::{GpuConfig, Scheme};
use malekeh::harness::{geomean, Table};
use malekeh::sim::run_benchmark;
use malekeh::trace::{table2, Suite};

fn naive_matmul(x: &[f32], y: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let a = x[i * k + p];
            for j in 0..n {
                c[i * n + j] += a * y[p * n + j];
            }
        }
    }
    c
}

fn main() {
    // --- 1. the real tensor-core kernel through the PJRT bridge ---
    match malekeh::runtime::Runtime::open_default() {
        Ok(mut rt) => {
            let (m, k, n) = (256, 256, 256);
            let x: Vec<f32> = (0..m * k).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
            let y: Vec<f32> = (0..k * n).map(|i| ((i % 7) as f32 - 3.0) * 0.2).collect();
            let t0 = std::time::Instant::now();
            let c = rt.gemm(&x, &y, m, k, n).expect("gemm artifact");
            let dt = t0.elapsed();
            let want = naive_matmul(&x, &y, m, k, n);
            let max_err = c
                .iter()
                .zip(want.iter())
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            println!(
                "mma_gemm artifact: {m}x{k}x{n} f32 GEMM in {:.1} ms, max |err| vs rust = {max_err:.2e}",
                dt.as_secs_f64() * 1e3
            );
            assert!(max_err < 1e-2, "artifact numerics diverged");
        }
        Err(e) => println!("(artifacts not built; skipping PJRT GEMM check: {e})"),
    }

    // --- 2. the Deepbench suite through the simulator ---
    let schemes = [Scheme::BASELINE, Scheme::MALEKEH, Scheme::BOW, Scheme::MALEKEH_PR];
    let mut t = Table::new(
        "Deepbench: IPC (norm) and RF-cache hit ratio per scheme",
        &["bench", "mal_ipc", "bow_ipc", "pr_ipc", "mal_hit", "bow_hit", "pr_hit"],
    );
    let mut norm: Vec<Vec<f64>> = vec![Vec::new(); 3];
    for b in table2().filter(|b| b.suite == Suite::Deepbench) {
        let mut ipc = [0f64; 4];
        let mut hit = [0f64; 4];
        for (i, s) in schemes.iter().enumerate() {
            let mut cfg = GpuConfig::table1_baseline().with_scheme(*s);
            cfg.num_sms = 2;
            let stats = run_benchmark(&cfg, b.name, 2);
            ipc[i] = stats.ipc();
            hit[i] = stats.rf_hit_ratio();
        }
        for i in 0..3 {
            norm[i].push(ipc[i + 1] / ipc[0].max(1e-9));
        }
        t.row_f(
            b.name,
            &[
                ipc[1] / ipc[0],
                ipc[2] / ipc[0],
                ipc[3] / ipc[0],
                hit[1],
                hit[2],
                hit[3],
            ],
            3,
        );
    }
    t.row_f(
        "GEOMEAN",
        &[
            geomean(&norm[0]),
            geomean(&norm[1]),
            geomean(&norm[2]),
            0.0,
            0.0,
            0.0,
        ],
        3,
    );
    t.print();
}
